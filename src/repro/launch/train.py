"""End-to-end training driver: data -> sharded train step -> checkpoint
-> restart, with straggler monitoring and elastic mesh selection.

Fault-tolerance contract (the 1000+-node posture, exercised at CPU scale
by examples/ and tests/):

  * checkpoints are atomic + sharded (checkpoint/manager.py); the driver
    resumes from the latest COMPLETE step on any restart — node failure
    and planned restart are the same code path;
  * the mesh is chosen from the SURVIVING device count
    (runtime/mesh.py) so a restart on fewer hosts reshards the same
    checkpoint onto the smaller mesh — and re-resolves the op route
    under the new TP/EP degrees;
  * the data pipeline is stateless-resumable: batch i is a pure function
    of (seed, i), so only the step counter is checkpointed;
  * per-step wall-time telemetry flags stragglers (runtime/monitor.py);
  * optional residual-compensated gradient compression halves DP
    all-reduce wire bytes (optim/compression.py; the paper's Eq. 1).

Recommended XLA flags for real TPU runs (collective/compute overlap —
XLA's latency-hiding scheduler; recorded here, harmless on CPU):
  --xla_tpu_enable_data_parallel_all_reduce_opt=true
  --xla_tpu_data_parallel_opt_different_sized_ops=true
  --xla_enable_async_collective_permute=true

Usage (CPU-scale example):
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
      --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/run1
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, get_config, get_smoke
from repro.configs.base import execution_policy_for
from repro.core import ops
from repro.core.precision import PrecisionPolicy
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.models import api
from repro.optim import adamw
from repro.runtime import mesh as meshlib
from repro.runtime.monitor import StepMonitor, run_header
from repro.runtime.sharding import Sharder
from repro.runtime.train_step import make_train_step

__all__ = ["TrainLoop", "main"]


class TrainLoop:
    """Restart-safe training loop over one (config, policy, mesh)."""

    def __init__(self, cfg, *, policy: PrecisionPolicy,
                 opt_cfg: adamw.AdamWConfig, data_cfg: DataConfig,
                 ckpt_dir: str | None = None, microbatches: int = 1,
                 remat: bool = True, ckpt_every: int = 25,
                 use_mesh: bool = False,
                 mesh: "meshlib.MeshSpec | None" = None):
        self.cfg = cfg
        self.opt_cfg = opt_cfg
        self.data_cfg = data_cfg
        self.ckpt_every = ckpt_every
        self.mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.monitor = StepMonitor()

        # `mesh` is the explicit MeshSpec (--mesh dp=2,tp=2,...);
        # `use_mesh` is the legacy boolean and means --mesh auto.
        spec = mesh
        if spec is None and use_mesh and jax.device_count() > 1:
            spec = meshlib.mesh_spec_for(jax.device_count(), cfg)
        self.mesh = self.sharder = None
        if spec is not None and not spec.is_identity:
            self.mesh = meshlib._mesh_for_spec(spec)
            if isinstance(policy, ops.ExecutionPolicy):
                # Thread the mesh through the policy: routed ops run
                # their shard_map variants, re-validated against each
                # impl's Partitioning capability.
                if policy.mesh != spec:
                    policy = dataclasses.replace(policy, mesh=spec)
                self.sharder = Sharder(cfg, self.mesh, policy=policy)
            else:
                self.sharder = Sharder(cfg, self.mesh)
        self.policy = policy

        step_fn = make_train_step(cfg, opt_cfg, policy,
                                  microbatches=microbatches, remat=remat)
        if self.sharder is not None:
            aparams = jax.eval_shape(
                lambda: api.init_params(jax.random.PRNGKey(0), cfg))
            pspecs = self.sharder.param_specs(aparams)
            ospecs = adamw.AdamWState(
                step=self.sharder.ns(jax.sharding.PartitionSpec()),
                m=pspecs, v=pspecs)
            # out_shardings pinned to the in_shardings: shard_map'd ops
            # may bias XLA toward a different inferred output layout,
            # which trips the donation sharding check on step 2.
            self.step_fn = jax.jit(
                step_fn, in_shardings=(pspecs, ospecs, None),
                out_shardings=(pspecs, ospecs, None),
                donate_argnums=(0, 1))
        else:
            self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    # ------------------------------------------------------------ state

    def init_or_restore(self, seed: int = 0):
        params = api.init_params(jax.random.PRNGKey(seed), self.cfg)
        opt = adamw.init(params)
        start = 0
        if self.mgr is not None:
            self.mgr.clean_tmp()          # crash garbage from a prior run
            latest = self.mgr.latest_step()
            if latest is not None:
                abstract = jax.eval_shape(lambda: (params, opt))
                params, opt = self.mgr.restore(latest, abstract)
                start = latest
        return params, opt, start

    # -------------------------------------------------------------- run

    def run(self, steps: int, *, seed: int = 0, log_every: int = 10,
            fail_at_step: int | None = None):
        """Train to `steps`. `fail_at_step` injects a crash (tests)."""
        params, opt, start = self.init_or_restore(seed)
        ds = SyntheticLMDataset(self.data_cfg)
        history = []
        ctx = self.mesh if self.mesh is not None else _nullcontext()
        with ctx:
            for i in range(start, steps):
                if fail_at_step is not None and i == fail_at_step:
                    raise RuntimeError(f"injected failure at step {i}")
                batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
                self.monitor.start()
                params, opt, metrics = self.step_fn(params, opt, batch)
                stats = self.monitor.stop()
                history.append(float(metrics["loss"]))
                if stats.straggler:
                    print(f"[straggler] step {i}: {stats.last_s:.3f}s "
                          f"vs median {stats.median_s:.3f}s", flush=True)
                if log_every and (i + 1) % log_every == 0:
                    print(f"step {i+1:5d} loss={history[-1]:.4f} "
                          f"gnorm={float(metrics['grad_norm']):.3f} "
                          f"lr={float(metrics['lr']):.2e} "
                          f"{stats.last_s*1e3:.0f}ms", flush=True)
                if self.mgr and (i + 1) % self.ckpt_every == 0:
                    self.mgr.save_async(i + 1, (params, opt))
        if self.mgr:
            self.mgr.wait()
            self.mgr.save(steps, (params, opt))
        return params, opt, history


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--logits-policy", default=None)
    ap.add_argument("--backend", action="append", default=None,
                    metavar="[FAMILY=]IMPL",
                    help="op-registry routing, repeatable: "
                         "'family=impl' per kernel family "
                         f"(families: {', '.join(ops.families())}; "
                         "see `python -m benchmarks.run --list`). A "
                         "bare impl name means gemm=IMPL (deprecated). "
                         "Defaults: the arch's backends mapping")
    ap.add_argument("--attn-backend", default=None,
                    help="DEPRECATED: alias for --backend "
                         "attention=IMPL")
    ap.add_argument("--grouped-backend", default=None,
                    help="DEPRECATED: alias for --backend grouped=IMPL")
    ap.add_argument("--tile-cache", default=None, metavar="PATH",
                    help="JSON tile-autotune cache to load now and "
                         "persist autotune results to (also via the "
                         "REPRO_TILE_CACHE env var)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="device mesh: 'dp=2,tp=2,ep=2' (any subset), "
                         "'auto' (fit the visible device count, capped "
                         "at the arch's divisible TP/EP degrees), or "
                         "'none' (default, single-device). Composes "
                         "with --backend: every routed impl must "
                         "declare a Partitioning capability")
    ap.add_argument("--use-mesh", action="store_true",
                    help="DEPRECATED: alias for --mesh auto")
    args = ap.parse_args()

    if args.tile_cache:
        # The flag is both load source and persistence target — it must
        # override any inherited REPRO_TILE_CACHE, or autotune results
        # would save to a different file than the one just loaded.
        os.environ["REPRO_TILE_CACHE"] = args.tile_cache
    n = ops.load_tile_cache()         # flag or inherited REPRO_TILE_CACHE
    if n:
        print(f"tile cache: {n} shape(s) loaded from {ops.tile_cache_path()}")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    backends = ops.parse_backend_flags(
        args.backend, attn_backend=args.attn_backend,
        grouped_backend=args.grouped_backend)
    mesh_spec = meshlib.resolve_mesh_spec(
        meshlib.resolve_mesh_flag(args.mesh, args.use_mesh), cfg)
    # Route-build validation: training differentiates through every
    # routed op, so demand the vjp capability of each family's impl.
    policy = execution_policy_for(
        cfg, default=args.policy, logits=args.logits_policy,
        backends=backends,
        require={fam: ("vjp",) for fam in ops.families()},
        mesh=mesh_spec)
    print(run_header(args.arch, policy=policy, mesh=policy.mesh), flush=True)
    data_cfg = DataConfig(
        global_batch=args.batch, seq_len=args.seq,
        vocab_size=cfg.vocab_size,
        frames_dim=cfg.d_model if cfg.family == "audio" else 0,
        frames_seq=cfg.encoder_seq if cfg.family == "audio" else 0,
        image_tokens=cfg.num_image_tokens if cfg.family == "vlm" else 0,
        image_dim=cfg.d_model if cfg.family == "vlm" else 0)
    loop = TrainLoop(
        cfg, policy=policy,
        opt_cfg=adamw.AdamWConfig(lr=args.lr, total_steps=args.steps),
        data_cfg=data_cfg, ckpt_dir=args.ckpt_dir,
        microbatches=args.microbatches, ckpt_every=args.ckpt_every,
        mesh=mesh_spec)
    t0 = time.time()
    _, _, hist = loop.run(args.steps)
    print(f"\ntrained {len(hist)} steps in {time.time()-t0:.1f}s; "
          f"loss {hist[0]:.3f} -> {hist[-1]:.3f}")


if __name__ == "__main__":
    main()
