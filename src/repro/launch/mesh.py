"""DEPRECATED shim: mesh construction moved to ``runtime.mesh``.

``make_production_mesh`` / ``make_test_mesh`` now live in
``repro.runtime.mesh`` (one mesh module shared by both launchers and
the elastic path); this module re-exports them so pre-unification
imports keep working.
"""

from __future__ import annotations

from repro.runtime.mesh import (  # noqa: F401
    MeshSpec,
    make_production_mesh,
    make_test_mesh,
)

__all__ = ["MeshSpec", "make_production_mesh", "make_test_mesh"]
