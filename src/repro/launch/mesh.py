"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before any jax
initialization; tests import this module under a 1-device runtime).

Single pod: (data=16, model=16) = 256 chips (v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis
carries only data-parallel gradient reductions (DESIGN.md §5), so it
maps onto the slower inter-pod fabric.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, axes, devices=jax.devices()[:n])


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU distribution tests (subprocess sets device count)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
