"""Batched serving driver: continuous-batching loop over prefill +
single-token decode with a pre-allocated, shardable KV cache.

Serving model (the decode_32k / long_500k cells' runtime twin):
  * requests enter an admission queue; a free batch slot is assigned;
  * prefill ingests the prompt and splices the slot's cache region;
  * every engine tick decodes ONE token for ALL slots at their OWN
    per-slot positions (the jit'd cell from serve_step.make_engine_tick)
    — slots admitted at different ticks attend, rotate and write their
    KV rows at different absolute positions;
  * per-slot active/EOS/length lifecycle masking happens in-graph; the
    host reads back only small (B,) vectors per tick, never the logits;
  * finished slots are recycled for queued requests.

A staggered batch therefore produces token-for-token the same outputs
as serving each request alone (tests/test_serve_consistency.py).

On real hardware the tick is jit'd once against the full-capacity cache
and slots are swapped in place; this CPU-scale driver runs the same
code paths with smoke configs (examples/serve_batched.py).
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke
from repro.configs.base import execution_policy_for
from repro.core import ops
from repro.core.ops import paged as paged_kv
from repro.core.precision import PrecisionPolicy
from repro.models import api
from repro.runtime import serve_step

__all__ = ["ServeEngine", "Request", "QueueFull", "RecoveryMismatch",
           "main"]


class _PageAllocator:
    """Host-side free list over ONE paged-pool capacity class.

    Physical page 0 is the reserved trash page (freed table entries
    point there) and is never handed out; allocation starts at page 1.
    ``alloc`` is all-or-nothing — a partially satisfiable request
    returns None so admission can keep the request queued instead of
    holding pages it cannot use (backpressure, not deadlock: frees are
    whole-request too, so a blocked head request always fits once
    enough slots recycle)."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))

    @property
    def available(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        self._free.extend(pages)


class QueueFull(RuntimeError):
    """Admission queue at capacity: the engine refuses the request
    instead of buffering unbounded work.  The gateway maps this to
    backpressure (HTTP 429 + Retry-After); batch drivers either retry
    or count the rejection."""

    def __init__(self, rid: int, depth: int, max_queue: int):
        super().__init__(
            f"request {rid}: admission queue full "
            f"({depth}/{max_queue} queued)")
        self.rid = rid
        self.depth = depth
        self.max_queue = max_queue


class RecoveryMismatch(RuntimeError):
    """Token-exact recovery failed: re-prefilling ``prompt +
    out_tokens[:-1]`` on the new replica predicted a different token
    than the one the dead replica had already emitted.  Under greedy
    decode and a deterministic policy this must never happen — it means
    the two replicas disagree numerically (e.g. a policy mismatch), so
    recovery refuses to silently fork the stream."""

    def __init__(self, rid: int, index: int, expected: int, got: int):
        super().__init__(
            f"request {rid}: recovery re-prefill predicted token {got} "
            f"at output index {index} but the original stream emitted "
            f"{expected} — replicas are not bit-identical under this "
            f"policy")
        self.rid = rid
        self.index = index
        self.expected = expected
        self.got = got


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    session: str | None = None   # pool-level affinity key (multi-turn)
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    # fault-tolerance surface: a deadline in ENGINE ticks (virtual
    # time, so it is deterministic and survives rehoming — ticks_used
    # rides on the request, not on any one engine's counter), and
    # terminal disposition flags.  ``recoveries`` counts how many times
    # the request was rehomed after a replica death.
    deadline_ticks: int | None = None
    ticks_used: int = 0
    cancelled: bool = False
    expired: bool = False
    recoveries: int = 0
    # latency accounting — MONOTONIC clock, seconds (a wall-clock step
    # under NTP would corrupt latency_s/queue_s); wall_time is the one
    # wall timestamp, kept for log attribution only.
    t_submit: float | None = None
    t_admit: float | None = None
    t_first: float | None = None   # first token emitted (TTFT end)
    t_done: float | None = None
    wall_time: float | None = None

    @property
    def latency_s(self) -> float | None:
        """Submit-to-completion latency (None until done)."""
        if self.t_submit is None or self.t_done is None:
            return None
        return self.t_done - self.t_submit

    @property
    def queue_s(self) -> float | None:
        """Time spent waiting for a free slot (None until admitted)."""
        if self.t_submit is None or self.t_admit is None:
            return None
        return self.t_admit - self.t_submit

    @property
    def ttft_s(self) -> float | None:
        """Submit-to-first-token latency (None until the prefill's
        sampled token lands)."""
        if self.t_submit is None or self.t_first is None:
            return None
        return self.t_first - self.t_submit


class ServeEngine:
    """Slot-based continuous-batching engine with per-slot positions.

    Slot state lives on device as (B,) vectors — last token, position,
    active mask, remaining-token budget — and the decode tick advances
    all of it inside one jit'd call. The host only touches per-slot
    state at admission (prefill + cache splice) and when draining the
    small per-tick token/finished vectors into Request objects.

    ``policy`` may be a plain ``PrecisionPolicy`` (XLA matmuls) or a
    ``core.ops.ExecutionPolicy`` (or legacy ``MatmulPolicy``) whose
    ``backends`` mapping routes every model matmul to a registered
    op-registry impl (pallas / pallas_fused / pallas_grouped / ...).
    """

    def __init__(self, cfg, *, batch_size: int, max_ctx: int,
                 policy: PrecisionPolicy | None = None, eos_id: int = 1,
                 max_queue: int | None = None, metrics=None,
                 replica: str = "0", kv_layout: str = "dense",
                 kv_page_size: int = 8, kv_quant: str | None = None,
                 kv_pages: int | None = None):
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r}; "
                             f"one of ('dense', 'paged')")
        if kv_quant is not None and kv_layout != "paged":
            raise ValueError("kv_quant requires kv_layout='paged'")
        self.cfg = cfg
        self.batch = batch_size
        self.max_ctx = max_ctx
        self.policy = policy or PrecisionPolicy.uniform("bf16")
        self.eos_id = eos_id
        # paged-KV mode: attention caches become shared page pools; the
        # engine owns the per-class host-side free lists (set by load())
        # and the per-slot page allocations.
        self.kv_layout = kv_layout
        self.kv_page_size = kv_page_size
        self.kv_quant = kv_quant
        self.kv_pages = kv_pages
        self._allocators: dict[int, _PageAllocator] = {}
        self._slot_pages: list[dict[int, list[int]] | None] = \
            [None] * batch_size
        # None = unbounded (legacy batch drivers); serving fronts set a
        # watermark so a stalled engine rejects instead of OOMing.
        self.max_queue = max_queue
        # duck-typed MetricsRegistry (counter/gauge/histogram methods);
        # None keeps the hot path metrics-free.
        self.metrics = metrics
        self.replica = replica
        self.params = None
        self._tick = jax.jit(serve_step.make_engine_tick(
            cfg, self.policy, eos_id=eos_id, max_ctx=max_ctx))
        self._prefill = jax.jit(
            serve_step.make_prefill(cfg, self.policy, s_ctx=max_ctx))
        # slot state (device-resident between ticks)
        self.cache = None
        self.slot_req: list[Request | None] = [None] * batch_size
        self.last_tok = jnp.zeros(batch_size, jnp.int32)
        self.pos = jnp.zeros(batch_size, jnp.int32)
        self.active = jnp.zeros(batch_size, bool)
        self.remaining = jnp.zeros(batch_size, jnp.int32)
        # admission queue + engine counters
        self.queue: collections.deque[Request] = collections.deque()
        self.ticks = 0
        self.tokens_generated = 0

    def load(self, params) -> None:
        self.params = params
        # cache in the activation dtype: decode writes splice activation
        # rows in, and a dtype mismatch would silently round-trip keys
        # through a narrower type only on the batched path
        dtype = jnp.dtype(self.cfg.activation_dtype)
        if self.kv_layout == "paged":
            self.cache = serve_step.init_paged_cache(
                self.cfg, self.batch, self.max_ctx,
                page_size=self.kv_page_size, quant=self.kv_quant,
                num_pages=self.kv_pages, dtype=dtype)
            classes = serve_step.paged_classes(
                self.cfg, self.batch, self.max_ctx,
                page_size=self.kv_page_size, num_pages=self.kv_pages)
            self._allocators = {cap: _PageAllocator(n)
                                for cap, n in classes.items()}
        else:
            self.cache = api.init_cache(
                self.cfg, self.batch, self.max_ctx, dtype)

    # ------------------------------------------------------------ slots

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def _validate(self, req: Request) -> None:
        n_img = (self.cfg.num_image_tokens
                 if self.cfg.family == "vlm" else 0)
        # a recovered request re-prefills prompt + out_tokens[:-1], so
        # THAT is the length that must fit the prefill context
        plen = len(req.prompt) + max(0, len(req.out_tokens) - 1)
        if n_img + plen >= self.max_ctx:
            raise ValueError(
                f"request {req.rid}: prompt length {plen}"
                f"{f' (+{n_img} image tokens)' if n_img else ''} does not "
                f"fit the engine context (max_ctx={self.max_ctx})")

    # -------------------------------------------------------- paged KV

    def _pages_needed(self, req: Request, cap: int) -> int:
        """Worst-case page demand of one request in a capacity class.

        Linear layers touch rows [0, prompt+budget); ring layers wrap
        into at most ``cap`` slots — ``min(cap, total)`` covers both."""
        n_img = (self.cfg.num_image_tokens
                 if self.cfg.family == "vlm" else 0)
        total = n_img + len(req.prompt) + req.max_new_tokens
        return paged_kv.num_logical_pages(min(cap, total),
                                          self.kv_page_size)

    def _alloc_pages(self, req: Request) -> dict[int, list[int]] | None:
        """All-or-nothing allocation across every capacity class."""
        got: dict[int, list[int]] = {}
        for cap, alloc in self._allocators.items():
            pages = alloc.alloc(self._pages_needed(req, cap))
            if pages is None:
                for c, p in got.items():
                    self._allocators[c].free(p)
                return None
            got[cap] = pages
        return got

    def _free_pages(self, alloc_map: dict[int, list[int]], *,
                    slot: int | None = None) -> None:
        """Return a request's pages to the free lists; when the slot's
        tables were written (it decoded), zero them too, so the freed
        pages can never be corrupted by the stale slot's continuing
        in-graph writes (inactive rows then write the trash page)."""
        for cap, pages in alloc_map.items():
            self._allocators[cap].free(pages)
        if slot is not None:
            for seg_key, pos_key, _, _ in serve_step.attn_cache_walk(
                    self.cfg, self.max_ctx):
                leaf = self.cache[seg_key][pos_key]
                self.cache[seg_key][pos_key] = dataclasses.replace(
                    leaf, page_table=leaf.page_table.at[:, slot].set(0))

    def _splice_paged(self, cache1, slot: int,
                      alloc_map: dict[int, list[int]]) -> None:
        """Write the slot's page-table rows and scatter its padded dense
        prefill KV into the allocated pages (quantizing when the pool is
        quantized).  Every layer of a capacity class shares the same
        page ids — each layer has its OWN pool array, so equal ids never
        collide across layers."""
        ps = self.kv_page_size
        for seg_key, pos_key, _, cap in serve_step.attn_cache_walk(
                self.cfg, self.max_ctx):
            leaf = self.cache[seg_key][pos_key]
            dense = cache1[seg_key][pos_key]   # AttnCache (count,1,cap,..)
            n_log = leaf.page_table.shape[-1]
            row = np.zeros(n_log, np.int32)
            pages = alloc_map[cap]
            row[:len(pages)] = pages           # tail stays on trash (0)
            row_arr = jnp.asarray(row)

            def to_pages(x):
                # (count, 1, cap, Kv, hd) -> (count, n_log, ps, Kv, hd)
                x = x[:, 0].astype(jnp.float32)
                pad = [(0, 0)] * x.ndim
                pad[1] = (0, n_log * ps - x.shape[1])
                x = jnp.pad(x, pad)
                return x.reshape(x.shape[0], n_log, ps, *x.shape[2:])

            kp, vp = to_pages(dense.k), to_pages(dense.v)
            if leaf.quantized:
                qk, sk = paged_kv.quantize_rows(kp)
                qv, sv = paged_kv.quantize_rows(vp)
                leaf = dataclasses.replace(
                    leaf,
                    k_pages=leaf.k_pages.at[:, row_arr].set(qk),
                    v_pages=leaf.v_pages.at[:, row_arr].set(qv),
                    k_scale=leaf.k_scale.at[:, row_arr].set(sk),
                    v_scale=leaf.v_scale.at[:, row_arr].set(sv),
                    page_table=leaf.page_table.at[:, slot].set(row_arr))
            else:
                leaf = dataclasses.replace(
                    leaf,
                    k_pages=leaf.k_pages.at[:, row_arr].set(
                        kp.astype(leaf.k_pages.dtype)),
                    v_pages=leaf.v_pages.at[:, row_arr].set(
                        vp.astype(leaf.v_pages.dtype)),
                    page_table=leaf.page_table.at[:, slot].set(row_arr))
            self.cache[seg_key][pos_key] = leaf

    # -------------------------------------------------------- metrics
    # All no-ops when self.metrics is None: the registry is duck-typed
    # so launch/ never imports the serve package (pool/gateway import
    # THIS module).

    def _m_queue_depth(self) -> None:
        if self.metrics is not None:
            self.metrics.gauge(
                "serve_queue_depth",
                "requests awaiting a free slot").set(
                    len(self.queue), replica=self.replica)

    def _m_occupancy(self) -> None:
        if self.metrics is not None:
            occupied = sum(r is not None for r in self.slot_req)
            self.metrics.gauge(
                "serve_slot_occupancy",
                "fraction of decode slots holding a request").set(
                    occupied / self.batch, replica=self.replica)

    def submit(self, req: Request) -> None:
        """Queue a request for admission at the next free slot.

        Raises ValueError up front for prompts that cannot fit the
        engine context (so an oversized request never poisons the
        queue) and QueueFull when the admission queue is at its
        ``max_queue`` watermark — bounded admission is what lets the
        gateway translate overload into backpressure instead of
        unbounded memory growth.
        """
        self._validate(req)
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.metrics is not None:
                self.metrics.counter(
                    "serve_requests_rejected",
                    "submissions refused at the queue watermark").inc(
                        replica=self.replica)
            raise QueueFull(req.rid, len(self.queue), self.max_queue)
        if req.t_submit is None:
            req.t_submit = time.monotonic()
            req.wall_time = time.time()
        self.queue.append(req)
        if self.metrics is not None:
            self.metrics.counter(
                "serve_requests_submitted",
                "requests accepted into the admission queue").inc(
                    replica=self.replica)
            self._m_queue_depth()

    def admit(self, req: Request) -> bool:
        """Prefill `req` into a free slot. Returns False if none free.

        Single-request prefill: runs the prompt through the prefill path
        and splices the resulting caches into the batch cache at the
        slot index (tree-wise dynamic update on the batch axis). The
        prompt's first sampled token counts against max_new_tokens and
        may itself be EOS — then the request completes without ever
        occupying a decode slot.

        A request arriving with ``out_tokens`` already populated is a
        RECOVERY re-admission (its previous replica died mid-decode):
        the engine re-prefills ``prompt + out_tokens[:-1]`` and checks
        that the prefill's greedy next token equals the last token the
        dead replica emitted — under greedy decode this pins the resumed
        stream bit-identical to an undisturbed run (the same invariant
        that makes staggered admission token-exact).  A disagreement
        raises ``RecoveryMismatch`` rather than silently forking the
        stream.  No token is appended and nothing is re-counted: the
        recovered tokens were already generated once.
        """
        slot = self._free_slot()
        if slot is None:
            return False
        self._validate(req)
        if req.t_submit is None:
            req.t_submit = time.monotonic()
            req.wall_time = time.time()
        alloc_map = None
        if self.kv_layout == "paged":
            # Reserve pages BEFORE the prefill: worst-case demand is a
            # pure function of prompt length + token budget, so a
            # pool-pressure refusal costs nothing — the request stays
            # queued with no speculative first token to roll back.
            # (Recovery demand is identical: prompt + budget is
            # unchanged by rehoming.)
            alloc_map = self._alloc_pages(req)
            if alloc_map is None:
                return False
        n_img = (self.cfg.num_image_tokens
                 if self.cfg.family == "vlm" else 0)
        resume = len(req.out_tokens) > 0
        toks = (np.concatenate([np.asarray(req.prompt, np.int32),
                                np.asarray(req.out_tokens[:-1], np.int32)])
                if resume else np.asarray(req.prompt, np.int32))
        prompt = jnp.asarray(toks)[None]                    # (1, S[+k-1])
        batch = {"tokens": prompt}
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.float32)
        logits, cache1 = self._prefill(self.params, batch)
        first = int(jnp.argmax(logits[0, -1]))
        if resume:
            if first != req.out_tokens[-1]:
                if alloc_map is not None:
                    self._free_pages(alloc_map)
                raise RecoveryMismatch(
                    req.rid, len(req.out_tokens) - 1,
                    req.out_tokens[-1], first)
        else:
            req.t_admit = time.monotonic()
            req.out_tokens.append(first)
            req.t_first = time.monotonic()
            self.tokens_generated += 1
            if self.metrics is not None:
                self.metrics.histogram(
                    "serve_queue_wait_seconds",
                    "submit-to-admission wait").observe(
                        req.queue_s, replica=self.replica)
                self.metrics.histogram(
                    "serve_ttft_seconds",
                    "submit-to-first-token latency").observe(
                        req.ttft_s, replica=self.replica)
                # the prefill-sampled first token is generated HERE,
                # before the slot ever ticks — count it where it happens
                self.metrics.counter(
                    "serve_tokens", "decoded tokens").inc(
                        1, replica=self.replica)
        if (req.out_tokens[-1] == self.eos_id
                or len(req.out_tokens) >= req.max_new_tokens):
            # EOS (or an exhausted budget) straight out of prefill: the
            # request is done; the slot stays free for the next one
            # (its reserved pages go straight back — tables were never
            # written, so no zeroing is needed).
            req.done = True
            req.t_done = time.monotonic()
            if alloc_map is not None:
                self._free_pages(alloc_map)
            return True

        # The slot will actually decode: commit its prefill KV into the
        # batch cache (splice runs after the early-done check, so
        # requests that finish in prefill never touch the cache).
        def splice(full, one):
            if not hasattr(one, "shape") or one.ndim < 2:
                return full
            # leaves are (count, B, ...) stacked per segment
            return jax.lax.dynamic_update_index_in_dim(
                full, one[:, 0].astype(full.dtype), slot, axis=1)

        if self.kv_layout == "paged":
            # paged leaves take the page-scatter path; everything else
            # (cross-attn KV, recurrent state) splices densely as ever
            for sk, seg in cache1.items():
                for pk, one in seg.items():
                    full = self.cache[sk][pk]
                    if isinstance(full, paged_kv.PagedKVCache):
                        continue
                    self.cache[sk][pk] = jax.tree.map(splice, full, one)
            self._splice_paged(cache1, slot, alloc_map)
            self._slot_pages[slot] = alloc_map
        else:
            self.cache = jax.tree.map(splice, self.cache, cache1)
        # invariant (fresh k=1 and resumed k>1 alike): after k emitted
        # tokens the cache holds prompt + out[:k-1], the next input is
        # out[k-1] at position n_img + S + k - 1, and k counted against
        # the budget — so a resumed slot ticks exactly like the dead one
        # would have.
        self.slot_req[slot] = req
        self.last_tok = self.last_tok.at[slot].set(req.out_tokens[-1])
        self.pos = self.pos.at[slot].set(n_img + len(toks))
        self.active = self.active.at[slot].set(True)
        self.remaining = self.remaining.at[slot].set(
            req.max_new_tokens - len(req.out_tokens))
        return True

    # ------------------------------------------------------------- tick

    def tick(self) -> int:
        """One engine step: decode one token for every active slot.

        Every slot decodes at its OWN position (pos is a (B,) vector);
        lifecycle masking (inactive freeze, EOS, token budget, context
        bound) happens inside the jit'd tick. Returns the number of
        tokens decoded this tick (= active slots at entry).
        """
        active_before = np.asarray(self.active)
        n_active = int(active_before.sum())
        if n_active == 0:
            self._m_occupancy()
            return 0
        t0 = time.monotonic()
        (self.cache, self.last_tok, self.pos, self.remaining,
         self.active, finished) = self._tick(
            self.params, self.cache, self.last_tok, self.pos,
            self.active, self.remaining)
        nxt = np.asarray(self.last_tok)
        fin = np.asarray(finished)
        now = time.monotonic()
        for i in np.flatnonzero(active_before):
            r = self.slot_req[i]
            r.out_tokens.append(int(nxt[i]))
            if fin[i]:
                r.done = True
                r.t_done = now
                self.slot_req[i] = None
                if self.kv_layout == "paged" and self._slot_pages[i]:
                    self._free_pages(self._slot_pages[i], slot=int(i))
                    self._slot_pages[i] = None
        self.ticks += 1
        self.tokens_generated += n_active
        if self.metrics is not None:
            dt = now - t0
            self.metrics.histogram(
                "serve_tick_seconds",
                "one engine decode tick (all active slots)").observe(
                    dt, replica=self.replica)
            # one tick = one token per active slot, so per-slot
            # inter-token latency IS the tick duration
            self.metrics.histogram(
                "serve_inter_token_seconds",
                "per-slot inter-token latency").observe(
                    dt, replica=self.replica)
            self.metrics.counter(
                "serve_tokens", "decoded tokens").inc(
                    n_active, replica=self.replica)
            self.metrics.gauge(
                "serve_tokens_per_s",
                "decode throughput over the last tick").set(
                    n_active / max(dt, 1e-9), replica=self.replica)
            self._m_occupancy()
        return n_active

    def step(self) -> int:
        """Expire overdue work, admit as many queued requests as slots
        allow, tick, then age every request still in flight (deadlines
        count engine steps of ownership, so they are deterministic in
        virtual time and survive rehoming to another replica)."""
        self._expire_due()
        while self.queue and self.admit(self.queue[0]):
            self.queue.popleft()
        self._m_queue_depth()
        n = self.tick()
        for r in self.queue:
            r.ticks_used += 1
        for r in self.slot_req:
            if r is not None:
                r.ticks_used += 1
        return n

    @property
    def idle(self) -> bool:
        return not self.queue and all(r is None for r in self.slot_req)

    # ------------------------------------------------- fault tolerance

    def _release_slot(self, slot: int) -> None:
        """Host-side slot teardown outside the normal finish path
        (cancellation, expiry, evacuation): unmask the slot from the
        jit'd tick and reclaim its pages.  The cache rows themselves
        need no scrubbing — an inactive slot is frozen in-graph and its
        region is overwritten by the next admission's splice."""
        self.slot_req[slot] = None
        self.active = self.active.at[slot].set(False)
        self.remaining = self.remaining.at[slot].set(0)
        if self.kv_layout == "paged" and self._slot_pages[slot]:
            self._free_pages(self._slot_pages[slot], slot=slot)
            self._slot_pages[slot] = None

    def _finish(self, req: Request, *, cancelled: bool = False,
                expired: bool = False) -> None:
        req.done = True
        req.cancelled = cancelled
        req.expired = expired
        req.t_done = time.monotonic()

    def _expire_due(self) -> list[Request]:
        """Terminate every request whose tick deadline has passed —
        queued or mid-decode — freeing its slot and pages."""
        expired: list[Request] = []
        for r in [r for r in self.queue
                  if r.deadline_ticks is not None
                  and r.ticks_used >= r.deadline_ticks]:
            self.queue.remove(r)
            self._finish(r, expired=True)
            expired.append(r)
        for i, r in enumerate(self.slot_req):
            if (r is not None and r.deadline_ticks is not None
                    and r.ticks_used >= r.deadline_ticks):
                self._finish(r, expired=True)
                self._release_slot(i)
                expired.append(r)
        if expired and self.metrics is not None:
            self.metrics.counter(
                "serve_requests_expired",
                "requests terminated at their tick deadline").inc(
                    len(expired), replica=self.replica)
        return expired

    def cancel(self, rid: int) -> bool:
        """Abort a request by id (client disconnect): drop it from the
        queue or free its decode slot + KV pages.  Returns False when
        the request is unknown or already done."""
        for i, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                self._finish(r, cancelled=True)
                self._release_slot(i)
                break
        else:
            for r in self.queue:
                if r.rid == rid:
                    self.queue.remove(r)
                    self._finish(r, cancelled=True)
                    break
            else:
                return False
        if self.metrics is not None:
            self.metrics.counter(
                "serve_requests_cancelled",
                "requests aborted before completion "
                "(client disconnect)").inc(replica=self.replica)
        return True

    def evacuate(self) -> list[Request]:
        """Strip every unfinished request off this engine, freeing all
        slots and pages, and return them (decoding slots in slot order
        with their partial ``out_tokens``, then the queue in FIFO
        order) so the pool can rehome them.  Purely host-side
        bookkeeping — safe to run on a crashed replica whose device
        state is unreachable."""
        orphans: list[Request] = []
        for i, r in enumerate(self.slot_req):
            if r is not None:
                self._release_slot(i)
                if not r.done:
                    orphans.append(r)
        while self.queue:
            r = self.queue.popleft()
            if not r.done:
                orphans.append(r)
        return orphans

    def pages_outstanding(self) -> int:
        """KV pages currently held by slots (leak audit: must be 0 on
        an idle engine; dense engines report 0)."""
        return sum(a.num_pages - 1 - a.available
                   for a in self._allocators.values())

    def stats(self, requests: list[Request], wall_s: float) -> dict:
        lat = [r.latency_s for r in requests if r.latency_s is not None]
        qs = [r.queue_s for r in requests if r.queue_s is not None]
        return {
            "requests": len(requests),
            "ticks": self.ticks,
            "tokens": self.tokens_generated,
            "wall_s": wall_s,
            "tok_per_s": self.tokens_generated / max(wall_s, 1e-9),
            "latency_mean_s": float(np.mean(lat)) if lat else 0.0,
            "latency_max_s": float(np.max(lat)) if lat else 0.0,
            "queue_mean_s": float(np.mean(qs)) if qs else 0.0,
        }

    def run(self, requests: list[Request]) -> dict:
        """Serve all requests to completion; returns throughput stats.

        Token accounting happens inside tick()/admit() — counted at
        decode time, BEFORE finished slots are recycled, so the final
        token of every request (and the prefill-sampled first token) is
        included.
        """
        t0 = time.monotonic()
        ticks0, tokens0 = self.ticks, self.tokens_generated
        for req in requests:
            self.submit(req)
        guard = 0
        while not self.idle:
            self.step()
            guard += 1
            if guard > 10_000:
                raise RuntimeError("serve loop did not converge")
        stats = self.stats(requests, time.monotonic() - t0)
        # per-RUN deltas: the engine counters are lifetime-cumulative
        stats["ticks"] -= ticks0
        stats["tokens"] -= tokens0
        stats["tok_per_s"] = stats["tokens"] / max(stats["wall_s"], 1e-9)
        return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-ctx", type=int, default=64)
    ap.add_argument("--policy", default="bf16",
                    help="default precision policy for every matmul")
    ap.add_argument("--kv-layout", choices=("dense", "paged"),
                    default="dense",
                    help="attention KV cache layout: 'dense' per-slot "
                         "ring buffers, or 'paged' fixed-size pages "
                         "behind a per-slot page table (allocate on "
                         "admit, free on slot recycle)")
    ap.add_argument("--kv-page-size", type=int, default=8,
                    help="rows per KV page (paged layout only)")
    ap.add_argument("--kv-quant", choices=("none", "int8"),
                    default="none",
                    help="paged-page payload quantization: int8 pages "
                         "+ per-(row, kv-head) fp32 scales, dequantized "
                         "at read time")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="pages per pool class (default: full capacity "
                         "+ trash page — lossless; smaller pools trade "
                         "admission backpressure for memory)")
    ap.add_argument("--deadline-ticks", type=int, default=None,
                    help="per-request deadline in engine ticks: work "
                         "still queued or decoding after this many "
                         "ticks of ownership is expired in-engine "
                         "(slot + KV pages freed). Default: none")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind a least-loaded router "
                         "with session affinity (repro.serve.pool); 1 "
                         "= the single in-process engine")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="per-replica admission-queue watermark; past "
                         "it submissions raise QueueFull (the gateway "
                         "maps this to HTTP 429 + Retry-After). "
                         "Default: unbounded")
    ap.add_argument("--gateway-port", type=int, default=None,
                    help="serve an asyncio HTTP/JSON gateway (token "
                         "streaming, /metrics, backpressure) on this "
                         "port instead of running the synthetic batch")
    ap.add_argument("--backend", action="append", default=None,
                    metavar="[FAMILY=]IMPL",
                    help="op-registry routing, repeatable: "
                         "'family=impl' per kernel family "
                         f"(families: {', '.join(ops.families())}; "
                         "see `python -m benchmarks.run --list`). A "
                         "bare impl name means gemm=IMPL (deprecated). "
                         "Defaults: the arch's backends mapping")
    ap.add_argument("--attn-backend", default=None,
                    help="DEPRECATED: alias for --backend "
                         "attention=IMPL")
    ap.add_argument("--grouped-backend", default=None,
                    help="DEPRECATED: alias for --backend grouped=IMPL")
    ap.add_argument("--mesh", default=None, metavar="SPEC",
                    help="device mesh: 'dp=2,tp=2,ep=2' (any subset), "
                         "'auto' (fit the visible device count), or "
                         "'none' (default, single-device). Composes "
                         "with --backend: every routed impl must "
                         "declare a Partitioning capability")
    ap.add_argument("--tile-cache", default=None, metavar="PATH",
                    help="JSON tile-autotune cache: loaded at startup "
                         "so restarts skip re-tuning hot shapes, and "
                         "the persistence target for new autotune "
                         "results (also via REPRO_TILE_CACHE)")
    args = ap.parse_args()

    if args.tile_cache:
        # The flag is both load source and persistence target — it must
        # override any inherited REPRO_TILE_CACHE, or autotune results
        # would save to a different file than the one just loaded.
        os.environ["REPRO_TILE_CACHE"] = args.tile_cache
    n = ops.load_tile_cache()         # flag or inherited REPRO_TILE_CACHE
    if n:
        print(f"tile cache: {n} shape(s) loaded from {ops.tile_cache_path()}")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    backends = ops.parse_backend_flags(
        args.backend, attn_backend=args.attn_backend,
        grouped_backend=args.grouped_backend)
    from repro.runtime import mesh as meshlib
    from repro.runtime.monitor import run_header
    mesh_spec = meshlib.resolve_mesh_spec(args.mesh, cfg)
    # Route-build validation: the engine tick decodes against the KV
    # cache every step, so demand the attention impl's decode capability
    # up front instead of failing on the first tick (and paged_decode
    # too when the engine runs the paged layout).
    attn_caps = (("decode", "paged_decode")
                 if args.kv_layout == "paged" else ("decode",))
    policy = execution_policy_for(
        cfg, default=args.policy, backends=backends,
        require={"attention": attn_caps}, mesh=mesh_spec)
    kv_kwargs = dict(
        kv_layout=args.kv_layout, kv_page_size=args.kv_page_size,
        kv_quant=None if args.kv_quant == "none" else args.kv_quant,
        kv_pages=args.kv_pages)
    print(run_header(args.arch, policy=policy, mesh=policy.mesh), flush=True)
    params = api.init_params(jax.random.PRNGKey(0), cfg)

    if args.replicas > 1 or args.gateway_port is not None:
        # serve-stack path: replica pool (least-loaded routing, session
        # affinity), optionally fronted by the HTTP gateway. Imported
        # lazily — repro.serve imports THIS module.
        from repro.serve.metrics import MetricsRegistry
        from repro.serve.pool import ReplicaPool
        registry = MetricsRegistry()

        def factory(idx, pol):
            eng = ServeEngine(cfg, batch_size=args.batch,
                              max_ctx=args.max_ctx, policy=pol,
                              max_queue=args.max_queue, metrics=registry,
                              replica=str(idx), **kv_kwargs)
            eng.load(params)
            return eng

        pool = ReplicaPool(
            cfg, params, replicas=args.replicas,
            batch_size=args.batch, max_ctx=args.max_ctx,
            policy=policy, max_queue=args.max_queue, metrics=registry,
            engine_factory=(factory if args.kv_layout == "paged"
                            else None))
        if args.gateway_port is not None:
            import asyncio

            from repro.serve.gateway import Gateway
            gw = Gateway(pool, host="0.0.0.0", port=args.gateway_port,
                         metrics=registry)
            print(f"gateway: listening on :{args.gateway_port} "
                  f"({args.replicas} replica(s), "
                  f"max_queue={args.max_queue})", flush=True)
            asyncio.run(gw.serve_forever())
            return
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(
                            2, cfg.vocab_size,
                            args.prompt_len).astype(np.int32),
                        max_new_tokens=args.max_new,
                        deadline_ticks=args.deadline_ticks)
                for i in range(args.requests)]
        stats = pool.run(reqs)
        print(f"pool served {stats['requests']} requests across "
              f"{stats['replicas']} replicas ({stats['wall_s']:.2f}s, "
              f"{stats['tok_per_s']:.1f} tok/s)")
        for r in reqs[:3]:
            print(f"  req {r.rid}: {len(r.out_tokens)} tokens "
                  f"{r.out_tokens[:8]}...")
        return

    eng = ServeEngine(cfg, batch_size=args.batch, max_ctx=args.max_ctx,
                      policy=policy, max_queue=args.max_queue,
                      **kv_kwargs)
    eng.load(params)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new,
                    deadline_ticks=args.deadline_ticks)
            for i in range(args.requests)]
    stats = eng.run(reqs)
    print(f"served {stats['requests']} requests in {stats['ticks']} ticks "
          f"({stats['wall_s']:.2f}s, {stats['tok_per_s']:.1f} tok/s, "
          f"mean latency {stats['latency_mean_s'] * 1e3:.0f}ms)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
