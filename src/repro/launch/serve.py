"""Batched serving driver: continuous-batching loop over prefill +
single-token decode with a pre-allocated, shardable KV cache.

Serving model (the decode_32k / long_500k cells' runtime twin):
  * requests arrive with a prompt; a batch slot is assigned;
  * prefill ingests the prompt and writes the slot's cache region;
  * every engine tick decodes ONE token for ALL active slots (the
    decode cell the dry-run lowers);
  * finished slots (EOS or max tokens) are freed for new requests.

On real hardware the decode step is jit'd once against the full-capacity
cache and slots are swapped in place; this CPU-scale driver runs the
same code paths with smoke configs (examples/serve_batched.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke
from repro.core.precision import PrecisionPolicy
from repro.models import api
from repro.runtime import serve_step

__all__ = ["ServeEngine", "Request", "main"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Fixed-batch continuous-batching engine (slot-based)."""

    def __init__(self, cfg, *, batch_size: int, max_ctx: int,
                 policy: PrecisionPolicy | None = None, eos_id: int = 1):
        self.cfg = cfg
        self.batch = batch_size
        self.max_ctx = max_ctx
        self.policy = policy or PrecisionPolicy.uniform("bf16")
        self.eos_id = eos_id
        self.params = None
        self._decode = jax.jit(serve_step.make_decode(cfg, self.policy))
        self._prefill = jax.jit(
            serve_step.make_prefill(cfg, self.policy, s_ctx=max_ctx))
        # slot state
        self.cache = None
        self.slot_req: list[Request | None] = [None] * batch_size
        self.slot_pos = np.zeros(batch_size, np.int32)

    def load(self, params) -> None:
        self.params = params
        self.cache = api.init_cache(self.cfg, self.batch, self.max_ctx)

    # ------------------------------------------------------------ slots

    def _free_slot(self) -> int | None:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        """Prefill `req` into a free slot. Returns False if none free.

        Single-request prefill: runs the prompt through the prefill path
        and splices the resulting caches into the batch cache at the
        slot index (tree-wise dynamic update on the batch axis).
        """
        slot = self._free_slot()
        if slot is None:
            return False
        prompt = jnp.asarray(req.prompt)[None]              # (1, S)
        batch = {"tokens": prompt}
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), jnp.float32)
        if self.cfg.family == "vlm":
            batch["image_embeds"] = jnp.zeros(
                (1, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.float32)
        logits, cache1 = self._prefill(self.params, batch)

        def splice(full, one):
            if not hasattr(one, "shape") or one.ndim < 2:
                return full
            # leaves are (count, B, ...) stacked per segment
            return jax.lax.dynamic_update_index_in_dim(
                full, one[:, 0].astype(full.dtype), slot, axis=1)

        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.slot_req[slot] = req
        n_img = (self.cfg.num_image_tokens
                 if self.cfg.family == "vlm" else 0)
        self.slot_pos[slot] = n_img + len(req.prompt)
        req.out_tokens.append(int(jnp.argmax(logits[0, -1])))
        return True

    # ------------------------------------------------------------- tick

    def tick(self) -> int:
        """One engine step: decode one token for every active slot.

        NOTE position handling: the jit'd decode step takes one scalar
        pos; slots admitted at different times have different positions,
        so the engine ticks the batch with per-slot last tokens and the
        max position, masking inactive slots. (Real deployments pass a
        per-slot position vector; the smoke models here use one scalar —
        acceptable because examples admit aligned batches.)
        """
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        last = np.zeros((self.batch, 1), np.int32)
        for i in active:
            last[i, 0] = self.slot_req[i].out_tokens[-1]
        pos = jnp.asarray(int(self.slot_pos[active].max()), jnp.int32)
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(last), pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
        done = 0
        for i in active:
            r = self.slot_req[i]
            r.out_tokens.append(int(nxt[i]))
            self.slot_pos[i] += 1
            if (nxt[i] == self.eos_id
                    or len(r.out_tokens) >= r.max_new_tokens
                    or self.slot_pos[i] >= self.max_ctx - 1):
                r.done = True
                self.slot_req[i] = None
                done += 1
        return done

    def run(self, requests: list[Request]) -> dict:
        """Serve all requests to completion; returns throughput stats."""
        pending = list(requests)
        t0 = time.time()
        ticks = tokens = 0
        while pending or any(r is not None for r in self.slot_req):
            while pending and self.admit(pending[0]):
                pending.pop(0)
            self.tick()
            ticks += 1
            tokens += sum(r is not None for r in self.slot_req)
            if ticks > 10_000:
                raise RuntimeError("serve loop did not converge")
        dt = time.time() - t0
        return {"requests": len(requests), "ticks": ticks,
                "wall_s": dt, "tok_per_s": tokens / max(dt, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="gemma3-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-ctx", type=int, default=64)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    eng = ServeEngine(cfg, batch_size=args.batch, max_ctx=args.max_ctx)
    eng.load(api.init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(2, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    stats = eng.run(reqs)
    print(f"served {stats['requests']} requests in {stats['ticks']} ticks "
          f"({stats['wall_s']:.2f}s, {stats['tok_per_s']:.1f} tok/s)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: {len(r.out_tokens)} tokens "
              f"{r.out_tokens[:8]}...")


if __name__ == "__main__":
    main()
