"""Zamba2-7B — Mamba2 backbone + SHARED attention blocks.
[arXiv:2411.15242] 81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000,
ssm_state=64.

Pattern: 13 periods of [5 mamba2 + 1 shared_attn] + 3 trailing mamba2 =
81 mixer layers. The shared_attn block's parameters are stored ONCE and
re-applied at every occurrence (zamba2's parameter-sharing trick); its
KV caches stay per-occurrence. SSM state is O(1) in sequence =>
long_500k RUNS.
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    d_model=3584,
    num_layers=81,
    segments=(Segment(("mamba2",) * 5 + ("shared_attn",), 13),
              Segment(("mamba2",), 3)),
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    mlp_kind="swiglu",
    ssm_state=64,
    ssm_head_dim=64,
    rope_theta=10_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", d_model=64, num_layers=7,
        segments=(Segment(("mamba2",) * 2 + ("shared_attn",), 2),
                  Segment(("mamba2",), 1)),
        vocab_size=256, num_heads=4, num_kv_heads=4, head_dim=16,
        d_ff=128, mlp_kind="swiglu", ssm_state=16, ssm_head_dim=16,
        supported_shapes=CONFIG.supported_shapes)
