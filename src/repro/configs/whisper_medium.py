"""Whisper-medium — encoder-decoder audio backbone (conv frontend STUB).
[arXiv:2212.04356] 24L enc + 24L dec, d_model=1024 16H (kv=16) d_ff=4096
vocab=51865, encoder_seq=1500 frames.

Per the assignment the conv frontend is stubbed: input_specs() provides
precomputed frame embeddings (B, 1500, 1024). Learned positional
embeddings (rope_theta=None), biased projections, GELU MLP. The decoder
is full attention -> long_500k skipped; decode shapes run (the spec's
backbone shapes, not Whisper's own 448-token ceiling).
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    d_model=1024,
    num_layers=24,           # decoder mixer layers; encoder counted apart
    segments=(Segment(("attn", "cross_attn", "mlp"), 24),),
    encoder_segments=(Segment(("attn", "mlp"), 24),),
    encoder_layers=24,
    encoder_seq=1500,
    vocab_size=51865,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    mlp_kind="gelu",
    qkv_bias=True,
    mlp_bias=True,
    rope_theta=None,
    tie_embeddings=True,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", d_model=64, num_layers=2,
        segments=(Segment(("attn", "cross_attn", "mlp"), 2),),
        encoder_segments=(Segment(("attn", "mlp"), 2),),
        encoder_layers=2, encoder_seq=30, vocab_size=256,
        num_heads=4, num_kv_heads=4, head_dim=16, d_ff=128,
        mlp_kind="gelu", qkv_bias=True, mlp_bias=True, rope_theta=None,
        tie_embeddings=True)
