"""StarCoder2-15B — dense GQA + RoPE code model.
[arXiv:2402.19173; hf] 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.

Pure full attention -> long_500k skipped. Non-gated GELU MLP (d_ff=4d).
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    d_model=6144,
    num_layers=40,
    segments=(Segment(("attn", "mlp"), 40),),
    vocab_size=49152,
    num_heads=48,
    num_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    mlp_kind="gelu",
    qkv_bias=True,
    mlp_bias=True,
    rope_theta=100_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense", d_model=64, num_layers=2,
        segments=(Segment(("attn", "mlp"), 2),), vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        mlp_kind="gelu", qkv_bias=True, mlp_bias=True)
