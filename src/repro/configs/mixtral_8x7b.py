"""Mixtral 8x7B — MoE (8 experts, top-2) + sliding-window attention.
[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, window=4096.

SWA caps every KV cache at the 4096 window => long_500k RUNS with a
ring-buffer cache. Expert einsums are the paper's Fig.-7 batched-GEMM
regime. 8 experts do NOT divide the 16-way model axis, so experts stay
replicated and the FFN hidden dim takes TP (see runtime/sharding.py);
dbrx (16 experts) exercises true expert parallelism instead.
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    d_model=4096,
    num_layers=32,
    segments=(Segment(("attn_local", "moe"), 32),),
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    mlp_kind="swiglu",
    num_experts=8,
    top_k=2,
    window=4096,
    rope_theta=1_000_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe", d_model=64, num_layers=2,
        segments=(Segment(("attn_local", "moe"), 2),), vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        mlp_kind="swiglu", num_experts=4, top_k=2, window=16,
        supported_shapes=CONFIG.supported_shapes)
