"""Nemotron-4 340B — dense GQA + squared-ReLU MLP.
[arXiv:2402.16819] 96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

Pure full attention -> long_500k skipped (DESIGN.md). The 18432-wide
GEMMs are the paper's large-N error-growth regime; the logits matmul
(vocab 256k) defaults to a refined policy under PrecisionPolicy.mixed_hpc.
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    d_model=18432,
    num_layers=96,
    segments=(Segment(("attn", "mlp"), 96),),
    vocab_size=256000,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    mlp_kind="squared_relu",
    rope_theta=10_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemotron-smoke", family="dense", d_model=64, num_layers=2,
        segments=(Segment(("attn", "mlp"), 2),), vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        mlp_kind="squared_relu")
