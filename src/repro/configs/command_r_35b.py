"""Command-R 35B — dense GQA, no biases.
[hf:CohereForAI/c4ai-command-r-v01] 40L d_model=8192 64H (GQA kv=8)
d_ff=22528 vocab=256000. Pure full attention -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    d_model=8192,
    num_layers=40,
    segments=(Segment(("attn", "mlp"), 40),),
    vocab_size=256000,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    mlp_kind="swiglu",
    rope_theta=8_000_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="command-r-smoke", family="dense", d_model=64, num_layers=2,
        segments=(Segment(("attn", "mlp"), 2),), vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        mlp_kind="swiglu")
