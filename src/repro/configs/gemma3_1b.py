"""Gemma-3 1B — 5:1 local:global attention, 262k vocab.
[hf:google/gemma-3-1b-pt] 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144, sliding window 512.

5:1 local:global => only 5 of 26 layers carry a full-length KV cache;
local layers use the ring-buffer window cache => long_500k RUNS (the
sparse-global cache is sequence-sharded at that shape). The 262144-way
logits matmul is the showcase for the paper's refined policies.

Note: 4 heads do not divide the 16-way model axis; attention stays
head-replicated at this scale while the FFN (6912 = 16*432) takes TP.
"""

from repro.configs.base import ModelConfig, Segment

_PERIOD = ("attn_local", "mlp") * 5 + ("attn", "mlp")

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    num_layers=26,
    segments=(Segment(_PERIOD, 4), Segment(("attn_local", "mlp"), 2)),
    vocab_size=262144,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    mlp_kind="swiglu",   # geglu in the release; gated form retained
    window=512,
    rope_theta=1_000_000.0,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", d_model=64, num_layers=8,
        segments=(Segment(("attn_local", "mlp") * 2 + ("attn", "mlp"), 2),
                  Segment(("attn_local", "mlp"), 2)),
        vocab_size=512, num_heads=4, num_kv_heads=1, head_dim=16,
        d_ff=128, mlp_kind="swiglu", window=16, rope_theta=1_000_000.0,
        supported_shapes=CONFIG.supported_shapes)
