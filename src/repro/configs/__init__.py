"""Architecture registry + abstract input specs for the dry-run.

``get_config(arch)`` / ``get_smoke(arch)`` return the full / reduced
``ModelConfig``; ``input_specs(cfg, shape)`` returns weak-type-correct
``ShapeDtypeStruct`` stand-ins for every model input of that (arch x
shape) cell — shardable, zero allocation.
"""

from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import LM_SHAPES, ModelConfig, ShapeSpec

__all__ = ["ARCHS", "get_config", "get_smoke", "input_specs", "LM_SHAPES"]

_MODULES = {
    "rwkv6-7b": "rwkv6_7b",
    "nemotron-4-340b": "nemotron4_340b",
    "starcoder2-15b": "starcoder2_15b",
    "gemma3-1b": "gemma3_1b",
    "command-r-35b": "command_r_35b",
    "zamba2-7b": "zamba2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "dbrx-132b": "dbrx_132b",
    "whisper-medium": "whisper_medium",
    "internvl2-76b": "internvl2_76b",
}

ARCHS: tuple[str, ...] = tuple(_MODULES)


def _module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; one of {list(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke(arch: str) -> ModelConfig:
    return _module(arch).smoke()


def input_specs(cfg: ModelConfig, shape: ShapeSpec | str) -> dict:
    """Abstract batch inputs for one (arch x shape) cell.

    train/prefill: full-sequence tokens (+ stubbed modality embeddings).
    decode: one new token per sequence (the KV cache / recurrent state is
    a separate argument built by runtime.serve_step.abstract_cache).
    """
    if isinstance(shape, str):
        shape = LM_SHAPES[shape]
    b = shape.global_batch
    f32 = jnp.float32

    def tok(s):
        return jax.ShapeDtypeStruct((b, s), jnp.int32)

    if shape.mode in ("train", "prefill"):
        s = shape.seq_len
        specs: dict = {"tokens": tok(s)}
        if shape.mode == "train":
            specs["labels"] = tok(s)
        if cfg.family == "audio":
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), f32)
        if cfg.family == "vlm":
            specs["image_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), f32)
        return specs

    if shape.mode == "decode":
        return {
            "tokens": tok(1),
            # per-slot position vector: continuous batching admits rows
            # at different ticks, so every row has its own position
            "pos": jax.ShapeDtypeStruct((b,), jnp.int32),
        }
    raise ValueError(f"unknown mode {shape.mode!r}")
