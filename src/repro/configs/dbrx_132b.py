"""DBRX 132B — fine-grained MoE (16 experts, top-4).
[hf:databricks/dbrx-base] 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352.

16 experts divide the 16-way model axis exactly => true EXPERT
PARALLELISM (one expert per model-axis slice). Pure full attention ->
long_500k skipped.
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    d_model=6144,
    num_layers=40,
    segments=(Segment(("attn", "moe"), 40),),
    vocab_size=100352,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    mlp_kind="swiglu",
    num_experts=16,
    top_k=4,
    rope_theta=500_000.0,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dbrx-smoke", family="moe", d_model=64, num_layers=2,
        segments=(Segment(("attn", "moe"), 2),), vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        mlp_kind="swiglu", num_experts=4, top_k=2)
