"""InternVL2-76B — VLM: stubbed InternViT frontend + dense LM backbone.
[arXiv:2404.16821] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, 256 image tokens per sample (post pixel-shuffle).

Per the assignment the ViT tower is stubbed: input_specs() provides
projected patch embeddings (B, 256, 8192) prepended to the text stream.
Full attention backbone -> long_500k skipped.
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    d_model=8192,
    num_layers=80,
    segments=(Segment(("attn", "mlp"), 80),),
    vocab_size=128256,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    mlp_kind="swiglu",
    rope_theta=500_000.0,
    num_image_tokens=256,
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", d_model=64, num_layers=2,
        segments=(Segment(("attn", "mlp"), 2),), vocab_size=256,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
        mlp_kind="swiglu", num_image_tokens=8)
