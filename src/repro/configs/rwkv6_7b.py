"""RWKV-6 "Finch" 7B — attention-free, data-dependent decay.
[arXiv:2404.05892; hf] 32L d_model=4096 d_ff=14336 vocab=65536.

Attention-free => O(1)-state decode => runs the long_500k cell.
The paper's GEMM precision policy applies to every projection; the WKV
recurrence itself is VPU work (DESIGN.md §Arch-applicability).
"""

from repro.configs.base import ModelConfig, Segment

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    d_model=4096,
    num_layers=32,
    segments=(Segment(("rwkv6",), 32),),
    vocab_size=65536,
    d_ff=14336,
    rwkv_head_dim=64,
    rope_theta=None,
    supported_shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm", d_model=64, num_layers=2,
        segments=(Segment(("rwkv6",), 2),), vocab_size=256, d_ff=128,
        rwkv_head_dim=16, rope_theta=None,
        supported_shapes=CONFIG.supported_shapes)
