"""Model/config schema shared by all assigned architectures.

A model is a sequence of *segments*; each segment is a run of identical
layer "kinds" whose params are stacked on a leading axis and executed
with ``lax.scan`` (keeps HLO small for 26-96-layer stacks so the 80
dry-run compiles stay fast). Heterogeneous stacks (gemma3's 5 local : 1
global, zamba2's mamba + shared-attention interleave) become short
segment lists.

Layer kinds:
  attn          GQA self-attention sublayer (+RoPE, optional window)
  mlp           dense FFN sublayer (swiglu / squared_relu / gelu)
  moe           mixture-of-experts FFN sublayer
  mamba2        Mamba-2 SSD mixer sublayer
  rwkv6         RWKV-6 time-mix + channel-mix layer
  shared_attn   zamba2-style shared transformer block (params shared
                across all its occurrences; stored once, not stacked)
"""

from __future__ import annotations

import dataclasses
import warnings

from repro.core.matmul import MatmulPolicy
from repro.core.ops import ExecutionPolicy, TileConfig, normalize_backends

__all__ = ["Segment", "ModelConfig", "ShapeSpec", "LM_SHAPES",
           "execution_policy_for", "matmul_policy_for"]


@dataclasses.dataclass(frozen=True)
class Segment:
    """``count`` repetitions of the layer-kind tuple ``pattern``.

    E.g. gemma3: Segment(("attn_local", "mlp") * 5 + ("attn", "mlp"), 4)
    runs 4 periods of [5 local layers + 1 global layer].
    """

    pattern: tuple[str, ...]
    count: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    num_layers: int                  # total layers (bookkeeping; segments rule)
    segments: tuple[Segment, ...]
    vocab_size: int
    # attention
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    window: int | None = None        # sliding window for attn_local kind
    attn_logit_softcap: float | None = None
    qkv_bias: bool = False
    # ffn
    d_ff: int = 0
    mlp_kind: str = "swiglu"         # swiglu | squared_relu | gelu
    mlp_bias: bool = False
    # moe
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    conv_width: int = 4
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 64        # WKV chunk; §Perf B2: per-chunk overhead
                                # scales 1/C, the (C,C,K) tensor scales C
    # enc-dec (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0             # stubbed frontend: frames arrive embedded
    encoder_segments: tuple[Segment, ...] = ()
    # vlm (internvl2)
    num_image_tokens: int = 0
    # norm / misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    activation_dtype: str = "bfloat16"
    # which registered impl each op family runs by default for this
    # arch: a {family: impl} mapping over the repro.core.ops registry
    # ("gemm" / "attention" / "grouped", optionally layer-scoped keys
    # like "gemm@logits"; CLI --backend family=impl overrides).
    # Families absent here resolve to their reference impl.
    backends: tuple[tuple[str, str], ...] = ()
    # DEPRECATED per-family fields (the pre-registry surface): merged
    # into the ``backends`` mapping by execution_policy_for /
    # matmul_policy_for; explicit ``backends`` entries win.
    matmul_backend: str = "xla"
    attn_backend: str = "xla"
    grouped_backend: str = "xla"
    # which shapes this arch supports (long_500k dropped for pure full-attn)
    supported_shapes: tuple[str, ...] = (
        "train_4k", "prefill_32k", "decode_32k")

    def __post_init__(self) -> None:
        object.__setattr__(self, "backends",
                           normalize_backends(self.backends))
        # "num_layers" counts mixer sublayers (attn / mamba2 / rwkv6 /
        # shared_attn); mlp/moe sublayers ride along inside the same layer.
        mixers = sum(
            s.count * sum(k in ("attn", "attn_local", "mamba2", "rwkv6",
                                "shared_attn") for k in s.pattern)
            for s in self.segments)
        if mixers != self.num_layers:
            raise ValueError(
                f"{self.name}: segments define {mixers} mixer layers, "
                f"config says num_layers={self.num_layers}")

    @property
    def qk_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


def _arch_backends(cfg: ModelConfig) -> dict[str, str]:
    """The arch's default {family: impl} mapping: legacy per-family
    fields first, explicit ``cfg.backends`` entries win."""
    merged = {"gemm": cfg.matmul_backend, "attention": cfg.attn_backend,
              "grouped": cfg.grouped_backend}
    merged.update(dict(cfg.backends))
    return merged


def execution_policy_for(cfg: ModelConfig, *, default: str = "bf16",
                         logits: str | None = None,
                         backends=None,
                         tiles: TileConfig | None = None,
                         fallback: bool = False,
                         require=None, mesh=None) -> ExecutionPolicy:
    """The launch-script policy constructor: precision knobs from CLI
    flags, the op-family ``backends`` mapping from the repeatable
    ``--backend family=impl`` CLI overrides layered over the arch's
    defaults — validated against capability metadata at build time
    (``require`` adds feature demands, e.g. serve's attention decode;
    a non-identity ``mesh`` additionally demands Partitioning of every
    routed impl, so ``--mesh`` composes with ``--backend``)."""
    merged = _arch_backends(cfg)
    merged.update(dict(normalize_backends(backends or ())))
    return ExecutionPolicy(default=default, logits=logits, backends=merged,
                           tiles=tiles, fallback=fallback,
                           require=require or (), mesh=mesh)


def matmul_policy_for(cfg: ModelConfig, *, default: str = "bf16",
                      logits: str | None = None,
                      backend: str | None = None,
                      attn_backend: str | None = None,
                      grouped_backend: str | None = None,
                      tiles: TileConfig | None = None) -> MatmulPolicy:
    """DEPRECATED pre-registry policy constructor (one knob per kernel
    family); kept as a thin wrapper so old call sites and flags work.
    Use ``execution_policy_for(cfg, backends={family: impl})``."""
    warnings.warn(
        "matmul_policy_for is deprecated; use execution_policy_for(cfg, "
        "backends={'gemm': ..., 'attention': ..., 'grouped': ...})",
        DeprecationWarning, stacklevel=2)
    return MatmulPolicy(
        default=default, logits=logits,
        backend=backend if backend is not None else cfg.matmul_backend,
        attn_backend=(attn_backend if attn_backend is not None
                      else cfg.attn_backend),
        grouped_backend=(grouped_backend if grouped_backend is not None
                         else cfg.grouped_backend),
        tiles=tiles)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell (seq_len x global_batch + mode)."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


LM_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
